"""BulkMover — the Intel-DSA analogue: centralized, batched, async movement.

The paper's guidelines (§6) for bulk data movement between tiers:
  * use cache-bypassing paths (nt-store / movdir64B) — here the Pallas
    ``stream_copy`` kernel or XLA donated copies;
  * batch descriptors to amortize offload latency (Fig. 4b: batch 16/128);
  * submit asynchronously and overlap with compute;
  * limit concurrent writers to the slow tier (controller interference);
  * centralize movement in one daemon instead of per-application access.

``BulkMover`` is that daemon.  It executes real copies on the current
backend, records telemetry, and (because this box has one memory) also
reports *modeled* seconds from the calibrated perfmodel so benchmarks
can reproduce the paper's tier behaviour.

Movement drains through a pool of ``drain_workers`` threads (the DSA
engine count), so the slow-tier writer semaphore and the
``take_peak_writers`` watermark reflect *real* concurrency, not a
synthetic gauge.  Submissions are scheduled route-aware — descriptors
are batched per (src, dst, op) so one batch never mixes routes — and
through two priority lanes: ``LANE_LATENCY`` descriptors (demand
misses, SLO-pinned pages) jump ``LANE_BULK`` repartition traffic.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel
from repro.core.tiers import OpClass, TierSpec, TierTopology
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry

#: priority lanes — lower value drains first.
LANE_LATENCY = 0  #: latency-critical (demand fills, SLO-pinned pages)
LANE_BULK = 1  #: bulk background traffic (repartition, paging)


@dataclasses.dataclass
class Descriptor:
    """One movement request (DSA work descriptor analogue)."""

    src_tier: str
    dst_tier: str
    payload: Any  # jax/numpy array (or pytree) to move
    op: OpClass = OpClass.NT_STORE  # cache-bypass by default (guideline 1)
    on_done: Optional[Callable[[Any], None]] = None
    #: priority lane (LANE_LATENCY jumps LANE_BULK in the drain queue).
    lane: int = LANE_BULK
    #: buffer this traffic is billed to (arbiter attribution), if any.
    source: Optional[str] = None
    #: fused on-route dtype cast (compressed staging): the executor casts
    #: while moving, so the bytes on the wire are the POST-cast bytes.
    out_dtype: Optional[Any] = None
    #: per-descriptor completion handle, set by :meth:`BulkMover.issue`.
    future: Optional["MoveFuture"] = None

    @property
    def nbytes(self) -> int:
        """Bytes actually on the route.  With a fused cast the payload
        never travels at its source width — billing the pre-cast size
        would over/under-charge the arbiter (ISSUE 7 satellite)."""
        leaves = jax.tree_util.tree_leaves(self.payload)
        if self.out_dtype is not None:
            item = np.dtype(self.out_dtype).itemsize
            return sum(x.size * item for x in leaves)
        return sum(x.size * x.dtype.itemsize for x in leaves)

    @property
    def route(self) -> tuple[str, str, OpClass]:
        return (self.src_tier, self.dst_tier, self.op)


@dataclasses.dataclass
class Completion:
    descriptor: Descriptor
    result: Any
    wall_seconds: float
    modeled_seconds: float


class MoveFuture:
    """Per-descriptor completion handle (the non-blocking issue path).

    ``BulkMover.issue`` attaches one of these to every descriptor and
    returns them immediately; the drain worker fulfils each as its
    descriptor executes.  Callers overlap the migration with compute and
    either poll :meth:`done` at epoch boundaries or fence on
    :meth:`result` when they genuinely need the moved bytes."""

    __slots__ = ("_event", "_completion")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._completion: Optional[Completion] = None

    def _fulfil(self, completion: Completion) -> None:
        self._completion = completion
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float = 60.0) -> Completion:
        if not self._event.wait(timeout):
            raise TimeoutError("MoveFuture.result timed out")
        assert self._completion is not None
        return self._completion


def _execute_copy(payload, out_dtype=None):
    """Materialize a fresh copy on the current backend (the actual move).

    Host (numpy) payloads copy with a plain memcpy — routing them
    through XLA costs ~ms of dispatch per descriptor, which would put
    the movement daemon back ON the critical path it exists to clear.
    ``out_dtype`` fuses the compressed-staging cast into the move."""
    def _copy(x):
        if isinstance(x, np.ndarray):
            return np.array(x) if out_dtype is None else x.astype(out_dtype)
        x = jnp.asarray(x)
        return x.copy() if out_dtype is None else x.astype(out_dtype)

    out = jax.tree_util.tree_map(_copy, payload)
    jax.block_until_ready([
        x for x in jax.tree_util.tree_leaves(out)
        if not isinstance(x, np.ndarray)
    ])
    return out


def stream_executor(block_rows: int = 256, *, block_bytes_hint: int = 1 << 20
                    ) -> Callable[[Any, Any], Any]:
    """Executor that moves device payloads through the double-buffered
    Pallas ``stream_copy`` migration kernel (HBM -> VMEM staging -> HBM
    with overlapped async DMAs and the dtype cast fused in VMEM).

    2-D jax leaves take the kernel directly; higher-rank jax leaves are
    viewed as (rows, features) first (a free reshape); host numpy leaves
    keep the memcpy path — there is no DMA engine to overlap on host
    memory, and XLA dispatch would dominate.  The returned callable is
    flagged ``pipelined`` so ``BulkMover.modeled_cost`` switches to the
    overlapped-migration perfmodel."""
    from repro.kernels.stream_copy import ops as _stream_ops

    def _execute(payload, out_dtype=None):
        def _copy(x):
            if isinstance(x, np.ndarray):
                return (np.array(x) if out_dtype is None
                        else x.astype(out_dtype))
            x = jnp.asarray(x)
            if x.ndim == 0 or x.size == 0:
                return x.astype(out_dtype) if out_dtype else x.copy()
            flat = x.reshape(x.shape[0], -1) if x.ndim != 2 else x
            out = _stream_ops.stream_copy(flat, out_dtype=out_dtype,
                                          block_rows=block_rows)
            return out.reshape(x.shape)

        out = jax.tree_util.tree_map(_copy, payload)
        jax.block_until_ready([
            x for x in jax.tree_util.tree_leaves(out)
            if not isinstance(x, np.ndarray)
        ])
        return out

    _execute.pipelined = True
    _execute.block_bytes = block_bytes_hint
    return _execute


class BulkMover:
    """Centralized movement engine: batching, asynchrony, writer limits,
    a multi-worker drain pool, and priority-lane scheduling."""

    def __init__(
        self,
        topology: TierTopology,
        *,
        batch_size: int = 16,
        asynchronous: bool = True,
        max_writers: int = 2,
        max_readers: int = 8,
        drain_workers: int = 1,
        telemetry: Telemetry = GLOBAL_TELEMETRY,
        execute: Callable[[Any], Any] = _execute_copy,
    ):
        if batch_size < 1:
            raise ValueError("batch_size >= 1")
        if drain_workers < 1:
            raise ValueError("drain_workers >= 1")
        self.topology = topology
        self.batch_size = batch_size
        self.asynchronous = asynchronous
        self.max_writers = max_writers
        self.max_readers = max_readers
        self.drain_workers = drain_workers
        self.telemetry = telemetry
        self._execute = execute
        # Custom executors predating the fused-cast path take (payload)
        # only; pass out_dtype through only when the callable accepts it.
        try:
            n_params = len(inspect.signature(execute).parameters)
        except (TypeError, ValueError):
            n_params = 1
        self._execute_takes_dtype = n_params >= 2
        #: executor uses the double-buffered migration kernel — modeled
        #: costs switch to the overlapped-pipeline perfmodel.
        self.pipelined = bool(getattr(execute, "pipelined", False))
        self._pipeline_block_bytes = int(getattr(execute, "block_bytes",
                                                 1 << 20))
        # One writer semaphore PER slow device: the §6 writer limit is a
        # property of each device's controller (Fig. 3 collapse is per
        # controller), so concurrent writers into CXL-A must not throttle
        # CXL-B.  Created lazily per destination tier name.
        self._write_sems: dict[str, threading.Semaphore] = {}
        self._read_sem = threading.Semaphore(max_readers)
        # Writer-concurrency watermarks (global + per device): the §6
        # "limit concurrent writers" signal a controller (core/caption.py)
        # reads each epoch.
        self._writer_lock = threading.Lock()
        self._active_writers = 0
        self.peak_writers = 0
        self._active_by_dev: dict[str, int] = {}
        self.peak_by_dev: dict[str, int] = {}
        # Priority drain queue: entries are (lane, seq, batch); the seq
        # tiebreaker keeps FIFO order within a lane.  None batch = shutdown.
        self._queue: "queue.PriorityQueue[tuple[int, int, Optional[list[Descriptor]]]]" = (
            queue.PriorityQueue())
        self._seq = itertools.count()
        self._completions: "queue.Queue[Completion]" = queue.Queue()
        self._pending = 0
        self._pending_lock = threading.Lock()
        # Guards the closed flag vs queue puts: without it a submit racing
        # close() could enqueue batches after the workers consumed their
        # shutdown sentinels — work nobody drains, a silent wait_all hang.
        self._lifecycle = threading.Lock()
        self._closed = False
        # Lifetime submission counters (bench_hotpaths/tests introspection):
        # a run-coalesced actuator submits O(runs) descriptors for O(pages)
        # of payload, and these two watermarks make that ratio observable
        # without spelunking telemetry.
        self.descriptors_submitted = 0
        self.bytes_submitted = 0
        self._workers: list[threading.Thread] = []
        if asynchronous:
            for i in range(drain_workers):
                t = threading.Thread(target=self._drain, daemon=True,
                                     name=f"bulkmover-drain-{i}")
                t.start()
                self._workers.append(t)

    # -- cost modeling -------------------------------------------------------
    def _tier(self, name: str) -> TierSpec:
        return self.topology.by_name(name)

    def update_topology(self, topology: TierTopology) -> None:
        """Swap the topology after an elastic add/remove.

        A removed device should stay ledger-visible in the new topology
        (``TierTopology.remove_device(keep_visible=True)``) so queued
        descriptors naming it keep costing and billing; a hot-added
        device must be present before the first descriptor routes to it.
        Per-device writer semaphores/watermarks are keyed by name and
        created lazily, so they carry across the swap untouched."""
        self.topology = topology

    def modeled_cost(self, descs: Sequence[Descriptor]) -> float:
        """Modeled seconds for a descriptor set (DSA model): descriptors
        grouped per route; batching amortizes submission overhead."""
        routes: dict[tuple, list[Descriptor]] = {}
        for d in descs:
            routes.setdefault(d.route, []).append(d)
        total = 0.0
        for (src, dst, op), group in routes.items():
            kwargs = dict(
                n_descriptors=len(group),
                batch_size=self.batch_size,
                asynchronous=self.asynchronous,
                op=op,
                n_streams=min(self.max_writers,
                              self._tier(dst).store_peak_streams),
            )
            if self.pipelined:
                cost = perfmodel.pipelined_move_cost(
                    self._tier(src), self._tier(dst),
                    sum(d.nbytes for d in group),
                    block_bytes=self._pipeline_block_bytes, **kwargs)
            else:
                cost = perfmodel.bulk_move_cost(
                    self._tier(src), self._tier(dst),
                    sum(d.nbytes for d in group), **kwargs)
            total += cost.seconds
        return total

    # -- scheduling ----------------------------------------------------------
    def _schedule(self, descs: Sequence[Descriptor]) -> list[list[Descriptor]]:
        """Route-aware batch formation: one batch never mixes (src, dst, op)
        routes or lanes, so per-batch telemetry and the modeled DSA batch
        cost attribute cleanly.  Latency-lane batches sort first."""
        groups: dict[tuple, list[Descriptor]] = {}
        for d in descs:
            groups.setdefault((d.lane,) + d.route, []).append(d)
        batches = []
        for key in sorted(groups, key=lambda k: k[0]):
            group = groups[key]
            for i in range(0, len(group), self.batch_size):
                batches.append(group[i : i + self.batch_size])
        return batches

    def _write_sem_for(self, dst: str) -> threading.Semaphore:
        with self._writer_lock:
            sem = self._write_sems.get(dst)
            if sem is None:
                sem = self._write_sems[dst] = threading.Semaphore(
                    self.max_writers)
            return sem

    # -- execution -----------------------------------------------------------
    def _run_batch(self, batch: list[Descriptor]) -> list[Completion]:
        out = []
        modeled = self.modeled_cost(batch)
        for d in batch:
            writes_slow = self._tier(d.dst_tier).link_bw is not None
            sem = (self._write_sem_for(d.dst_tier) if writes_slow
                   else self._read_sem)
            with _acquired(sem):
                if writes_slow:
                    with self._writer_lock:
                        self._active_writers += 1
                        self.peak_writers = max(self.peak_writers,
                                                self._active_writers)
                        dev = d.dst_tier
                        self._active_by_dev[dev] = (
                            self._active_by_dev.get(dev, 0) + 1)
                        self.peak_by_dev[dev] = max(
                            self.peak_by_dev.get(dev, 0),
                            self._active_by_dev[dev])
                t0 = time.perf_counter()
                try:
                    if self._execute_takes_dtype:
                        result = self._execute(d.payload, d.out_dtype)
                    else:
                        result = self._execute(d.payload)
                finally:
                    if writes_slow:
                        with self._writer_lock:
                            self._active_writers -= 1
                            self._active_by_dev[d.dst_tier] -= 1
                dt = time.perf_counter() - t0
            self.telemetry.record_move(
                d.src_tier, d.dst_tier, d.nbytes, dt, descriptors=1,
                batches=0, source=d.source)
            comp = Completion(d, result, dt, modeled / len(batch))
            if d.on_done is not None:
                d.on_done(result)
            if d.future is not None:
                d.future._fulfil(comp)
            out.append(comp)
        # One batch record per route present (submission batches are
        # route-pure, but sync callers may hand-build mixed batches; each
        # route must still see its own batch count, not batch[0]'s).
        for src, dst, _ in {d.route for d in batch}:
            self.telemetry.record_move(src, dst, 0, 0.0,
                                       descriptors=0, batches=1)
        return out

    def _drain(self):
        while True:
            _, _, batch = self._queue.get()
            if batch is None:
                return
            for comp in self._run_batch(batch):
                self._completions.put(comp)
            with self._pending_lock:
                self._pending -= len(batch)

    def submit(self, descs: Sequence[Descriptor]) -> list[Completion]:
        """Submit descriptors; sync mode returns completions immediately."""
        descs = list(descs)

        def count_accepted():
            # only ACCEPTED work bumps the observability counters — a
            # rejected submit (after close) must not skew the exact
            # billed-bytes assertions downstream
            self.descriptors_submitted += len(descs)
            self.bytes_submitted += sum(d.nbytes for d in descs)

        if not self.asynchronous:
            if self._closed:
                raise RuntimeError("BulkMover.submit() after close()")
            if not descs:
                return []
            count_accepted()
            order = {id(d): i for i, d in enumerate(descs)}
            out = []
            for b in self._schedule(descs):
                out.extend(self._run_batch(b))
            out.sort(key=lambda c: order[id(c.descriptor)])
            return out
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("BulkMover.submit() after close()")
            if not descs:
                return []
            count_accepted()
            with self._pending_lock:
                self._pending += len(descs)
            for b in self._schedule(descs):
                self._queue.put((b[0].lane, next(self._seq), b))
        return []

    def issue(self, descs: Sequence[Descriptor]) -> list["MoveFuture"]:
        """Non-blocking submit: returns one :class:`MoveFuture` per
        descriptor instead of fencing.  In async mode the call returns as
        soon as the batches are queued — the caller's decode steps run
        while the drain pool streams the copies, and completions are
        collected at the next epoch boundary (``poll`` /
        ``Future.done``).  In sync mode the copies execute inline and the
        futures come back already fulfilled, so callers need no mode
        branch."""
        descs = list(descs)
        futures = []
        for d in descs:
            if d.future is None:
                d.future = MoveFuture()
            futures.append(d.future)
        self.submit(descs)
        return futures

    @property
    def pending(self) -> int:
        """Descriptors submitted but not yet executed (async backlog)."""
        with self._pending_lock:
            return self._pending

    def take_peak_writers(self, device: Optional[str] = None) -> int:
        """Peak concurrent slow-tier writers since last call (then reset).

        With ``device`` (a slow tier name), the per-device watermark — the
        Fig. 3 collapse is per controller, so an N-device Caption loop
        reads each device's own writer pressure."""
        with self._writer_lock:
            if device is not None:
                peak = self.peak_by_dev.get(device, 0)
                self.peak_by_dev[device] = self._active_by_dev.get(device, 0)
                return peak
            peak, self.peak_writers = self.peak_writers, self._active_writers
            return peak

    def poll(self) -> list[Completion]:
        out = []
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def wait_all(self, timeout: float = 60.0) -> list[Completion]:
        """Fence: block until every submitted descriptor completed."""
        deadline = time.monotonic() + timeout
        out = []
        while True:
            out.extend(self.poll())
            with self._pending_lock:
                if self._pending == 0 and self._queue.empty():
                    out.extend(self.poll())
                    return out
            if time.monotonic() > deadline:
                raise TimeoutError("BulkMover.wait_all timed out")
            time.sleep(0.0005)

    def close(self):
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
            # Shutdown sentinels sort after every real lane: queued work
            # drains first, and no submit can slip in behind them.
            for _ in self._workers:
                self._queue.put((1 << 30, next(self._seq), None))
        for t in self._workers:
            t.join(timeout=5)
        self._workers = []

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _acquired:
    def __init__(self, sem: threading.Semaphore):
        self.sem = sem

    def __enter__(self):
        self.sem.acquire()

    def __exit__(self, *exc):
        self.sem.release()
        return False


def double_buffer(items: Iterable[Any], load: Callable[[Any], Any]) -> Iterator[Any]:
    """Prefetch-one pipeline: load(next) overlaps with consumer of current.

    The staging pattern for paged optimizer offload and the data pipeline —
    the software shape of DSA async mode.
    """
    it = iter(items)
    try:
        first = next(it)
    except StopIteration:
        return
    result = {}
    def _load(item, slot):
        result[slot] = load(item)
    cur_t = threading.Thread(target=_load, args=(first, 0))
    cur_t.start()
    slot = 0
    for nxt in it:
        nxt_t = threading.Thread(target=_load, args=(nxt, 1 - slot))
        nxt_t.start()
        cur_t.join()
        yield result.pop(slot)
        cur_t, slot = nxt_t, 1 - slot
    cur_t.join()
    yield result.pop(slot)
