"""BulkMover — the Intel-DSA analogue: centralized, batched, async movement.

The paper's guidelines (§6) for bulk data movement between tiers:
  * use cache-bypassing paths (nt-store / movdir64B) — here the Pallas
    ``stream_copy`` kernel or XLA donated copies;
  * batch descriptors to amortize offload latency (Fig. 4b: batch 16/128);
  * submit asynchronously and overlap with compute;
  * limit concurrent writers to the slow tier (controller interference);
  * centralize movement in one daemon instead of per-application access.

``BulkMover`` is that daemon.  It executes real copies on the current
backend, records telemetry, and (because this box has one memory) also
reports *modeled* seconds from the calibrated perfmodel so benchmarks
can reproduce the paper's tier behaviour.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import perfmodel
from repro.core.tiers import OpClass, TierSpec, TierTopology
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry


@dataclasses.dataclass
class Descriptor:
    """One movement request (DSA work descriptor analogue)."""

    src_tier: str
    dst_tier: str
    payload: Any  # jax/numpy array (or pytree) to move
    op: OpClass = OpClass.NT_STORE  # cache-bypass by default (guideline 1)
    on_done: Optional[Callable[[Any], None]] = None

    @property
    def nbytes(self) -> int:
        return sum(
            x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(self.payload)
        )


@dataclasses.dataclass
class Completion:
    descriptor: Descriptor
    result: Any
    wall_seconds: float
    modeled_seconds: float


def _execute_copy(payload):
    """Materialize a fresh copy on the current backend (the actual move)."""
    out = jax.tree_util.tree_map(lambda x: jnp.asarray(x).copy(), payload)
    jax.block_until_ready(out)
    return out


class BulkMover:
    """Centralized movement engine with batching, asynchrony, writer limits."""

    def __init__(
        self,
        topology: TierTopology,
        *,
        batch_size: int = 16,
        asynchronous: bool = True,
        max_writers: int = 2,
        max_readers: int = 8,
        telemetry: Telemetry = GLOBAL_TELEMETRY,
        execute: Callable[[Any], Any] = _execute_copy,
    ):
        if batch_size < 1:
            raise ValueError("batch_size >= 1")
        self.topology = topology
        self.batch_size = batch_size
        self.asynchronous = asynchronous
        self.max_writers = max_writers
        self.max_readers = max_readers
        self.telemetry = telemetry
        self._execute = execute
        self._write_sem = threading.Semaphore(max_writers)
        self._read_sem = threading.Semaphore(max_readers)
        # Writer-concurrency watermark: the §6 "limit concurrent writers"
        # signal a controller (core/caption.py) reads each epoch.
        self._writer_lock = threading.Lock()
        self._active_writers = 0
        self.peak_writers = 0
        self._queue: "queue.Queue[Optional[list[Descriptor]]]" = queue.Queue()
        self._completions: "queue.Queue[Completion]" = queue.Queue()
        self._pending = 0
        self._pending_lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        if asynchronous:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- cost modeling -------------------------------------------------------
    def _tier(self, name: str) -> TierSpec:
        return self.topology.by_name(name)

    def modeled_cost(self, descs: Sequence[Descriptor]) -> float:
        """Modeled seconds for a descriptor set (DSA model): descriptors
        grouped per route; batching amortizes submission overhead."""
        routes: dict[tuple, list[Descriptor]] = {}
        for d in descs:
            routes.setdefault((d.src_tier, d.dst_tier, d.op), []).append(d)
        total = 0.0
        for (src, dst, op), group in routes.items():
            cost = perfmodel.bulk_move_cost(
                self._tier(src), self._tier(dst),
                sum(d.nbytes for d in group),
                n_descriptors=len(group),
                batch_size=self.batch_size,
                asynchronous=self.asynchronous,
                op=op,
                n_streams=min(self.max_writers,
                              self._tier(dst).store_peak_streams),
            )
            total += cost.seconds
        return total

    # -- execution -----------------------------------------------------------
    def _run_batch(self, batch: list[Descriptor]) -> list[Completion]:
        out = []
        modeled = self.modeled_cost(batch)
        for d in batch:
            writes_slow = self._tier(d.dst_tier).link_bw is not None
            sem = self._write_sem if writes_slow else self._read_sem
            with _acquired(sem):
                if writes_slow:
                    with self._writer_lock:
                        self._active_writers += 1
                        self.peak_writers = max(self.peak_writers,
                                                self._active_writers)
                t0 = time.perf_counter()
                try:
                    result = self._execute(d.payload)
                finally:
                    if writes_slow:
                        with self._writer_lock:
                            self._active_writers -= 1
                dt = time.perf_counter() - t0
            self.telemetry.record_move(
                d.src_tier, d.dst_tier, d.nbytes, dt, descriptors=1, batches=0
            )
            comp = Completion(d, result, dt, modeled / len(batch))
            if d.on_done is not None:
                d.on_done(result)
            out.append(comp)
        self.telemetry.record_move(
            batch[0].src_tier, batch[0].dst_tier, 0, 0.0, descriptors=0, batches=1
        )
        return out

    def _drain(self):
        while True:
            batch = self._queue.get()
            if batch is None:
                return
            for comp in self._run_batch(batch):
                self._completions.put(comp)
            with self._pending_lock:
                self._pending -= len(batch)

    def submit(self, descs: Sequence[Descriptor]) -> list[Completion]:
        """Submit descriptors; sync mode returns completions immediately."""
        descs = list(descs)
        if not descs:
            return []
        if not self.asynchronous:
            out = []
            for i in range(0, len(descs), self.batch_size):
                out.extend(self._run_batch(descs[i : i + self.batch_size]))
            return out
        with self._pending_lock:
            self._pending += len(descs)
        for i in range(0, len(descs), self.batch_size):
            self._queue.put(descs[i : i + self.batch_size])
        return []

    def take_peak_writers(self) -> int:
        """Peak concurrent slow-tier writers since last call (then reset)."""
        with self._writer_lock:
            peak, self.peak_writers = self.peak_writers, self._active_writers
            return peak

    def poll(self) -> list[Completion]:
        out = []
        while True:
            try:
                out.append(self._completions.get_nowait())
            except queue.Empty:
                return out

    def wait_all(self, timeout: float = 60.0) -> list[Completion]:
        """Fence: block until every submitted descriptor completed."""
        deadline = time.monotonic() + timeout
        out = []
        while True:
            out.extend(self.poll())
            with self._pending_lock:
                if self._pending == 0 and self._queue.empty():
                    out.extend(self.poll())
                    return out
            if time.monotonic() > deadline:
                raise TimeoutError("BulkMover.wait_all timed out")
            time.sleep(0.0005)

    def close(self):
        if self._worker is not None:
            self._queue.put(None)
            self._worker.join(timeout=5)
            self._worker = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class _acquired:
    def __init__(self, sem: threading.Semaphore):
        self.sem = sem

    def __enter__(self):
        self.sem.acquire()

    def __exit__(self, *exc):
        self.sem.release()
        return False


def double_buffer(items: Iterable[Any], load: Callable[[Any], Any]) -> Iterator[Any]:
    """Prefetch-one pipeline: load(next) overlaps with consumer of current.

    The staging pattern for paged optimizer offload and the data pipeline —
    the software shape of DSA async mode.
    """
    it = iter(items)
    try:
        first = next(it)
    except StopIteration:
        return
    result = {}
    def _load(item, slot):
        result[slot] = load(item)
    cur_t = threading.Thread(target=_load, args=(first, 0))
    cur_t.start()
    slot = 0
    for nxt in it:
        nxt_t = threading.Thread(target=_load, args=(nxt, 1 - slot))
        nxt_t.start()
        cur_t.join()
        yield result.pop(slot)
        cur_t, slot = nxt_t, 1 - slot
    cur_t.join()
    yield result.pop(slot)
