"""Donation-based in-place buffer updates for tier actuation.

The last non-O(Δ) cost in the probe-epoch loop (ISSUE 7): a stable-path
repartition plans, ships, and re-indexes in O(Δ), but materializing the
functional update still paid one full copy-on-write of every RECEIVING
shard, because immutable jax buffers cannot be patched in place.  When
the caller provably drops the parent tensor — the Caption actuation
pattern ``it = it.repartition_weights(...)`` — that copy is pure waste:
XLA buffer *donation* lets the scatter reuse the input buffer, so the
update writes only the moved rows.

``donated_update`` is that path: a jitted ``donate_argnums=(0,)``
scatter shared by ``InterleavedTensor._scatter_bucketed``, the
stable-path ``repartition``, and ``TieredKVCache._retile``.  On this
CPU backend (jax >= 0.4.3x) donation is real — the output aliases the
input buffer (asserted by tests/test_actuation.py via
``unsafe_buffer_pointer``) — and on TPU/GPU it is the standard aliasing
path.  Index arrays are padded to power-of-two buckets (out-of-range
rows, dropped by the scatter) so a Caption walk's varying delta sizes
hit a bounded number of jit traces.

DONATION CONTRACT: passing ``donate=True`` anywhere upstream asserts
that the parent object — and any ancestor sharing the receiving
buffers — is dead after the call.  The parent's arrays are deleted
(accessing them raises).

VIEW HAZARD: a live zero-copy host view (``np.asarray(buf)``) pins an
external reference on the buffer, which blocks XLA input/output
aliasing — the "donated" call then silently materializes a full copy
(correct, but the O(Δ) win is gone).  Every donated call site must
drop its host mirrors / staged views of the receiving buffer first and
re-view the returned array; staging data must be gathered as copies
(fancy indexing), never as views.

``FULL_SHARD_COPIES`` counts every full receiving-shard copy the
non-donated paths still perform; benchmarks assert the donated stable
path leaves it at zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


class CopyCounter:
    """Counts full receiving-shard materializations (bench/test probe)."""

    __slots__ = ("count",)

    def __init__(self) -> None:
        self.count = 0

    def bump(self, n: int = 1) -> None:
        self.count += n

    def reset(self) -> int:
        out, self.count = self.count, 0
        return out


#: full copy-on-write shard materializations since last reset — the
#: quantity the donated path eliminates on the stable path.
FULL_SHARD_COPIES = CopyCounter()


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pad_to_bucket(rows: np.ndarray, values, n_rows: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Pad (rows, values) to the next power-of-two length.

    Pad rows point at ``n_rows`` (one past the end) and are dropped by
    the scatter's ``mode="drop"``; pad values are zeros.  Bounded bucket
    count = bounded jit traces across a walk of varying delta sizes."""
    rows = np.asarray(rows, np.int64)
    values = np.asarray(values)
    k = rows.shape[0]
    cap = _next_pow2(k)
    if cap == k:
        return rows, values
    rows_p = np.full((cap,), n_rows, np.int64)
    rows_p[:k] = rows
    vals_p = np.zeros((cap,) + values.shape[1:], values.dtype)
    vals_p[:k] = values
    return rows_p, vals_p


@functools.partial(jax.jit, donate_argnums=(0,), static_argnames=("op",))
def _donated_row_update(part, rows, values, op: str = "set"):
    ref = part.at[rows]
    if op == "set":
        return ref.set(values, mode="drop")
    return ref.add(values, mode="drop")


@functools.lru_cache(maxsize=64)
def _donated_row_update_sharded(op: str, sharding):
    # memory_kind backends must keep the output in the donated input's
    # memory space — out_shardings pins it (a bare jit could migrate the
    # result back to default device memory, silently un-tiering the shard).
    def fn(part, rows, values):
        ref = part.at[rows]
        if op == "set":
            return ref.set(values, mode="drop")
        return ref.add(values, mode="drop")

    return jax.jit(fn, donate_argnums=(0,), out_shardings=sharding)


def donated_update(part: jax.Array, rows, values, op: str = "set",
                   *, bucket: bool = True, out_sharding=None) -> jax.Array:
    """In-place (donated) row scatter: ``part[rows] = values`` reusing
    ``part``'s buffer.  The caller must own ``part`` exclusively (see
    the donation contract above); ``part`` is deleted on return.

    ``op`` is ``"set"`` or ``"add"`` (duplicates accumulate under add;
    set requires distinct rows, as everywhere else in the scatter
    stack).  With ``bucket`` the index/value arrays are padded to
    power-of-two lengths so delta-size churn stays within a bounded
    trace count.  ``out_sharding`` pins the output memory space (the
    ``memory_kind`` backend's pinned-host shards)."""
    if bucket:
        rows, values = pad_to_bucket(rows, values, part.shape[0])
    rows = jnp.asarray(rows)
    values = jnp.asarray(values, part.dtype)
    if out_sharding is not None:
        return _donated_row_update_sharded(op, out_sharding)(
            part, rows, values)
    return _donated_row_update(part, rows, values, op)


@functools.partial(jax.jit, donate_argnums=(0,))
def _donated_kv_update(pool, slots, rows, data):
    # pool: (L, B, T, K, hd); writes data (L, n_slots, n_rows, K, hd)
    # into the [slots x rows] page slabs of every layer at once.
    return pool.at[:, slots[:, None], rows[None, :]].set(data, mode="drop")


def donated_kv_update(pool: jax.Array, slots, rows, data) -> jax.Array:
    """In-place (donated) KV-pool page-slab scatter for ``_retile``:
    pool[:, slots, rows] = data, reusing ``pool``'s buffer.  Same
    exclusive-ownership contract as :func:`donated_update`."""
    return _donated_kv_update(pool, jnp.asarray(slots, jnp.int32),
                              jnp.asarray(rows, jnp.int32),
                              jnp.asarray(data, pool.dtype))
