"""MEMO — the paper's microbenchmark, re-expressed in JAX.

Two modes:

* **measure** — real timings on the current backend (this container's
  CPU; on a TPU runtime, HBM): sequential load/store/copy bandwidth vs
  lane count, random block access vs block size, and dependent
  pointer-chase latency.  These validate the *shape* of the perfmodel
  curves and give the kernel-level numbers in EXPERIMENTS.md.
* **simulate** — per-tier tables from the calibrated perfmodel
  (``repro.core.perfmodel``), reproducing the paper's Figs. 2/3/4/5 for
  the paper testbed and predicting the TPU v5e tier pair.

Lanes stand in for the paper's threads: MEMO shards the access across
``lanes`` independent slices inside one fused program, which is how
"concurrent streams" materialize on an XLA backend.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perfmodel
from repro.core.tiers import OpClass, TierSpec, TierTopology


@dataclasses.dataclass
class Record:
    name: str
    tier: str
    op: str
    lanes: int
    block_bytes: int
    seconds: float
    bytes_moved: int

    @property
    def gbps(self) -> float:
        return self.bytes_moved / self.seconds / 1e9 if self.seconds else 0.0

    def row(self) -> dict:
        return {
            "name": self.name, "tier": self.tier, "op": self.op,
            "lanes": self.lanes, "block_bytes": self.block_bytes,
            "seconds": self.seconds, "GBps": round(self.gbps, 3),
        }


def _time(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# --------------------------------------------------------------------------
# Real measurements (current backend)
# --------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("lanes",))
def _seq_load(x: jax.Array, lanes: int):
    xs = x.reshape(lanes, -1)
    return jnp.sum(xs, axis=1)


@partial(jax.jit, static_argnames=("lanes",), donate_argnums=0)
def _seq_store(x: jax.Array, lanes: int, v: jax.Array):
    xs = x.reshape(lanes, -1)
    return (xs * 0 + v[:, None]).reshape(x.shape)


@partial(jax.jit, donate_argnums=1)
def _seq_copy(src: jax.Array, dst: jax.Array):
    del dst
    return src + 0  # forced materialization = one read + one write stream


@partial(jax.jit, static_argnames=("block_elems",))
def _random_block_load(x: jax.Array, starts: jax.Array, block_elems: int):
    def body(acc, s):
        blk = jax.lax.dynamic_slice(x, (s,), (block_elems,))
        return acc + jnp.sum(blk), None
    acc, _ = jax.lax.scan(body, jnp.zeros((), x.dtype), starts)
    return acc


@jax.jit
def _pointer_chase(perm: jax.Array, steps: jax.Array):
    def body(i, p):
        return perm[p]
    return jax.lax.fori_loop(0, steps, body, jnp.zeros((), jnp.int32))


def measure_sequential(
    nbytes: int = 1 << 26, lanes_list: Sequence[int] = (1, 2, 4, 8)
) -> list[Record]:
    out = []
    n = nbytes // 4
    for lanes in lanes_list:
        nn = n - n % lanes
        x = jnp.arange(nn, dtype=jnp.float32)
        s = _time(_seq_load, x, lanes)
        out.append(Record("seq", "local", "load", lanes, nbytes, s, nn * 4))
        v = jnp.arange(lanes, dtype=jnp.float32)
        t0 = time.perf_counter()
        y = jax.block_until_ready(_seq_store(x, lanes, v))
        s = time.perf_counter() - t0
        out.append(Record("seq", "local", "store", lanes, nbytes, s, nn * 4))
        del y
    src = jnp.arange(n, dtype=jnp.float32)
    dst = jnp.zeros(n, dtype=jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(_seq_copy(src, dst))
    s = time.perf_counter() - t0
    out.append(Record("seq", "local", "copy", 1, nbytes, s, 2 * n * 4))
    return out


def measure_random_block(
    table_bytes: int = 1 << 26,
    block_bytes_list: Sequence[int] = (1024, 4096, 16384, 65536),
    n_blocks: int = 512,
    seed: int = 0,
) -> list[Record]:
    out = []
    n = table_bytes // 4
    x = jnp.arange(n, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    for bb in block_bytes_list:
        be = bb // 4
        starts = jnp.asarray(
            rng.integers(0, n - be, size=n_blocks, dtype=np.int64), jnp.int32
        )
        s = _time(_random_block_load, x, starts, be)
        out.append(Record("rand", "local", "load", 1, bb, s, n_blocks * bb))
    return out


def measure_pointer_chase(
    n_elems: int = 1 << 22, steps: int = 1 << 16, seed: int = 0
) -> Record:
    rng = np.random.default_rng(seed)
    perm = jnp.asarray(rng.permutation(n_elems).astype(np.int32))
    s = _time(_pointer_chase, perm, jnp.int32(steps))
    return Record("ptr-chase", "local", "load", 1, 4, s, steps * 4)


# --------------------------------------------------------------------------
# Simulated per-tier tables (calibrated perfmodel)
# --------------------------------------------------------------------------
def simulate_latency(topology: TierTopology) -> list[dict]:
    """Fig. 2 analogue: per-tier latency by instruction class."""
    rows = []
    for t in topology.tiers:
        rows.append({
            "tier": t.name,
            "ld_ns": t.load_latency_ns,
            "st_wb_ns": t.load_latency_ns * t.rfo_traffic_multiplier,
            "nt_st_ns": t.load_latency_ns * 0.75,
            "ptr_chase_ns": t.chase_latency_ns,
        })
    return rows


def simulate_seq_bw(
    topology: TierTopology, lanes: Sequence[int] = (1, 2, 4, 8, 12, 16, 24, 32)
) -> list[dict]:
    """Fig. 3 analogue: sequential bandwidth vs stream count per tier/op."""
    rows = []
    for t in topology.tiers:
        for op in (OpClass.LOAD, OpClass.STORE, OpClass.NT_STORE):
            for L in lanes:
                rows.append({
                    "tier": t.name, "op": op.value, "lanes": L,
                    "GBps": perfmodel.stream_bandwidth(t, op, L) / 1e9,
                })
    return rows


def simulate_random_bw(
    topology: TierTopology,
    blocks: Sequence[int] = (1024, 4096, 16384, 65536, 262144),
    lanes: Sequence[int] = (1, 2, 4, 8, 16),
) -> list[dict]:
    """Fig. 5 analogue."""
    rows = []
    for t in topology.tiers:
        for op in (OpClass.LOAD, OpClass.STORE, OpClass.NT_STORE):
            for bb in blocks:
                for L in lanes:
                    rows.append({
                        "tier": t.name, "op": op.value, "block": bb, "lanes": L,
                        "GBps": perfmodel.random_block_bandwidth(t, op, bb, L) / 1e9,
                    })
    return rows


def simulate_movement(
    topology: TierTopology,
    nbytes: int = 1 << 28,
    page_bytes: int = 4 << 10,
    batches: Sequence[int] = (1, 16, 128),
    engine_streams: int = 4,
) -> list[dict]:
    """Fig. 4b analogue: engine-offloaded bulk movement D2C/C2D/C2C/D2D.

    Tiered-memory systems move data at page granularity (4 KiB/2 MiB —
    paper §6); at 4 KiB the per-descriptor offload latency dominates and
    batching/asynchrony show exactly the Fig. 4b ordering.
    """
    fast, slow = topology.fast, topology.slow or topology.fast
    routes = {
        "D2D": (fast, fast), "D2C": (fast, slow),
        "C2D": (slow, fast), "C2C": (slow, slow),
    }
    n_desc = nbytes // page_bytes
    rows = []
    for route, (src, dst) in routes.items():
        for sync in (True, False):
            for b in batches:
                c = perfmodel.bulk_move_cost(
                    src, dst, nbytes, n_descriptors=n_desc, batch_size=b,
                    asynchronous=not sync, n_streams=engine_streams,
                )
                rows.append({
                    "route": route, "mode": "sync" if sync else "async",
                    "batch": b, "GBps": nbytes / c.seconds / 1e9,
                })
    return rows
