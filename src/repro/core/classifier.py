"""Latency-bound vs bandwidth-bound classification (paper §6.1).

The paper's final taxonomy: *latency-bound* applications (Redis — µs
responses, dependent single-stream accesses) degrade even with a small
slow-tier fraction and must stay fast-tier; *bandwidth-bound*
applications (DLRM embedding reduction — massively parallel streaming)
follow tier bandwidth and can even *gain* from interleaving when the
fast tier saturates.  ``classify`` operationalizes that decision from a
buffer's access profile so the planner can apply §6's guidelines
mechanically.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.core.tiers import TierSpec


class Boundedness(enum.Enum):
    LATENCY_BOUND = "latency"
    BANDWIDTH_BOUND = "bandwidth"
    COMPUTE_BOUND = "compute"


@dataclasses.dataclass(frozen=True)
class AccessProfile:
    """Per-step access behaviour of one buffer."""

    bytes_read_per_step: float
    bytes_written_per_step: float
    #: length of the dependent access chain (1 = fully parallel gather;
    #: large = pointer-chase / recurrent state update).
    dependent_chain: int
    #: number of independent access streams available to hide latency.
    parallelism: int
    #: typical contiguous access granularity in bytes.
    granularity: int
    #: compute time per step available to amortize access latency (s).
    compute_seconds: float = 0.0
    #: target response deadline, if any (s). µs-level deadlines are the
    #: paper's Redis case; ms-level is the DSB case.
    deadline_seconds: float | None = None

    @property
    def bytes_per_step(self) -> float:
        return self.bytes_read_per_step + self.bytes_written_per_step


def classify(profile: AccessProfile, tier: TierSpec) -> Boundedness:
    """Classify a buffer's access pattern against a candidate tier.

    Heuristic encoding of §6.1:
      * deep dependent chains with low parallelism are latency-bound
        unless per-hop latency is amortized by interleaved compute;
      * otherwise compare streaming time to compute time.
    """
    lat_s = tier.chase_latency_ns * 1e-9
    # Serial latency exposure: hops that cannot be overlapped.
    serial_hops = profile.dependent_chain / max(profile.parallelism, 1)
    latency_exposure = serial_hops * lat_s
    stream_time = profile.bytes_per_step / tier.load_bw if tier.load_bw else 0.0

    if profile.deadline_seconds is not None and profile.deadline_seconds < 100e-6:
        # µs-level SLO (Redis): any far-tier chase shows up in the tail.
        if latency_exposure > 0.05 * profile.deadline_seconds:
            return Boundedness.LATENCY_BOUND

    if latency_exposure > max(stream_time, profile.compute_seconds):
        return Boundedness.LATENCY_BOUND
    if stream_time > profile.compute_seconds:
        return Boundedness.BANDWIDTH_BOUND
    return Boundedness.COMPUTE_BOUND


def tolerates_slow_tier(profile: AccessProfile, slow: TierSpec) -> bool:
    """Paper guideline: offload only what amortizes the far tier's latency."""
    return classify(profile, slow) != Boundedness.LATENCY_BOUND


def classify_pool(profile: AccessProfile, topology) -> Boundedness:
    """Classify a profile against a topology's ACTIVE slow pool.

    Worst case across the slow devices: a buffer that is latency-bound
    against ANY device it could be interleaved onto must be treated as
    latency-bound for seeding (guideline 5 — one slow hop in a dependent
    chain is enough to show up in the tail).  With no slow devices the
    fast tier itself is the candidate (degenerate, never latency-bound
    in practice)."""
    tiers = topology.slows or (topology.fast,)
    verdicts = [classify(profile, t) for t in tiers]
    if Boundedness.LATENCY_BOUND in verdicts:
        return Boundedness.LATENCY_BOUND
    if Boundedness.BANDWIDTH_BOUND in verdicts:
        return Boundedness.BANDWIDTH_BOUND
    return verdicts[0]
