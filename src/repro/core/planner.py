"""Bandwidth-aware placement planner — the paper's guidelines, mechanized.

Given the access profile of every named buffer in a training/serving
step and a tier topology (one fast tier + N slow devices), produce a
placement plan that applies §6:

  1. latency-bound buffers (µs-SLO state, recurrent state, pointer-chase
     structures) are *pinned to the fast tier* (guideline: "avoid running
     µs-latency state entirely on CXL");
  2. if everything fits in the fast tier and the fast tier is not
     bandwidth-saturated, everything stays fast (Fig. 7: interleaving
     cannot beat pure DRAM for a latency-bound app);
  3. capacity overflow spills the *coldest tolerant* buffers (lowest
     bytes-touched-per-step per resident byte) to the slow devices in
     order via weighted N:M interleave;
  4. if the fast tier is bandwidth-bound (streamed bytes/step over fast
     bandwidth exceeds compute time), shift streaming bytes to the slow
     devices until per-step transfer times equalize — the Fig. 9 SNC
     result (+11% at 20% CXL) generalized:
        x* = (F*Bs - S*Bf) / (Bf + Bs)   bytes/step moved to slow,
     with ``Bs`` the *aggregate* slow bandwidth and the moved bytes
     split across devices proportional to each device's effective
     bandwidth (Fig. 10: the best static interleave ratio tracks the
     devices' relative bandwidths);
  5. write-heavy buffers have their slow fraction damped by the
     store/load bandwidth ratio and the writer limit (guideline: limit
     concurrent writers; RFO doubles temporal-store traffic);
  6. optionally, the plan is reconciled with the arbiter's bandwidth
     budget *up front* (``write_budget_bw``): when the aggregate
     slow-tier write demand exceeds the budget, the voluntary share of
     every buffer's slow fraction is scaled under it at plan time —
     starting the Caption fleet inside the feasible region instead of
     letting the arbiter clip from a bad start.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.classifier import AccessProfile, Boundedness, classify
from repro.core.ledger import TierLedger
from repro.core.policy import (BufferClass, MemPolicy,
                               largest_remainder_split)
from repro.core.tiers import OpClass, TierTopology


@dataclasses.dataclass(frozen=True)
class BufferReq:
    """One logical buffer the planner must place."""

    name: str
    klass: BufferClass
    nbytes: int
    profile: AccessProfile
    #: hard pin (e.g. staging buffers, decode state)
    pin_fast: bool = False
    #: page size for the interleave policy this buffer will use
    page_bytes: int = 2 * 1024 * 1024


@dataclasses.dataclass
class Decision:
    buffer: str
    policy: MemPolicy
    slow_fraction: float
    boundedness: Boundedness
    reason: str
    #: capacity floor: the slow fraction forced by fast-tier overflow.  A
    #: dynamic controller (core/caption.py) may tune the fraction but can
    #: never go below this without re-overflowing the fast tier.
    min_slow_fraction: float = 0.0
    #: per-slow-device page shares (by device name, summing to
    #: ``slow_fraction``) — the Caption weight-vector seed on an
    #: N-device topology.
    device_fractions: dict[str, float] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass
class Plan:
    decisions: dict[str, Decision]
    ledger: TierLedger
    est_fast_seconds: float
    est_slow_seconds: float
    est_step_seconds: float
    notes: list[str]

    def slow_fraction(self, name: str) -> float:
        return self.decisions[name].slow_fraction

    def report(self) -> str:
        lines = [
            f"{'buffer':<28s} {'class':<12s} {'bound':<10s} {'slow%':>6s}  reason"
        ]
        for d in self.decisions.values():
            lines.append(
                f"{d.buffer:<28s} {'':<12s} {d.boundedness.value:<10s}"
                f" {d.slow_fraction*100:5.1f}%  {d.reason}"
            )
        lines.append(self.ledger.report())
        lines.append(
            f"est step: fast {self.est_fast_seconds*1e3:.3f} ms / "
            f"slow {self.est_slow_seconds*1e3:.3f} ms / "
            f"total {self.est_step_seconds*1e3:.3f} ms"
        )
        return "\n".join(lines)


_LATENCY_CLASSES = {BufferClass.RECURRENT_STATE}


def _quantize_device_fractions(fr: dict, nbytes: int, free: dict,
                               denom: int = 64) -> dict:
    """Quantize per-device fractions onto the N:M cycle, rounding the
    TOTAL up (a capacity spill must never under-shoot the fast tier's
    room) and placing the round-up quanta only on devices with free
    capacity (largest fractional remainder first)."""
    import math
    total = min(sum(fr.values()), 1.0)
    if total <= 0:
        return {}
    q = 1.0 / denom
    want_units = min(math.ceil(total * denom - 1e-9), denom)
    names = list(fr)
    caps = [max(int((free.get(d, float("inf")) + 1e-9) / (q * nbytes)), 0)
            if nbytes else want_units for d in names]
    caps = [max(c, int(fr[d] * denom)) for c, d in zip(caps, names)]
    base, short = largest_remainder_split(
        [fr[d] * denom for d in names], want_units, caps)
    if short:  # nowhere with room: place anyway, let the ledger surface it
        i = max(range(len(names)), key=lambda j: free.get(names[j], 0.0))
        base[i] += short
    return {d: u * q for d, u in zip(names, base) if u > 0}


def plan(
    buffers: Sequence[BufferReq],
    topology: TierTopology,
    *,
    compute_seconds: float,
    reserve_fast_bytes: int = 0,
    fast_name: Optional[str] = None,
    slow_name: Optional[str] = None,
    write_budget_bw: Optional[float] = None,
) -> Plan:
    fast = topology.fast
    slows = topology.slows
    slow = topology.slow
    fast_name = fast_name or fast.name
    slow_name = slow_name or (slow.name if slow else fast.name)
    notes: list[str] = []
    ledger = TierLedger(topology)
    if reserve_fast_bytes:
        ledger.register("__reserved__", fast_name, reserve_fast_bytes,
                        note="activations/temps (XLA)", strict=False)

    #: per-buffer per-device fraction (device tier name -> share).
    dev_frac: dict[str, dict[str, float]] = {b.name: {} for b in buffers}
    bound: dict[str, Boundedness] = {}
    reason: dict[str, str] = {}
    tolerant: list[BufferReq] = []

    def frac_of(name: str) -> float:
        return sum(dev_frac[name].values())

    for b in buffers:
        bd = classify(b.profile, slow if slow else fast)
        bound[b.name] = bd
        if b.pin_fast or b.klass in _LATENCY_CLASSES or bd == Boundedness.LATENCY_BOUND:
            reason[b.name] = "latency-bound/pinned -> fast tier (guideline 5)"
        else:
            reason[b.name] = "fits fast"
            tolerant.append(b)

    if not slows:
        return _finalize(buffers, dev_frac, bound, reason,
                         {b.name: 0.0 for b in buffers}, ledger, topology,
                         fast_name, compute_seconds, notes)

    # --- step 3: capacity -----------------------------------------------
    fast_cap = fast.capacity_bytes - reserve_fast_bytes
    total_fast = sum(b.nbytes for b in buffers)
    if total_fast > fast_cap:
        notes.append(
            f"fast-tier overflow: {total_fast/2**30:.1f} GiB demand vs "
            f"{fast_cap/2**30:.1f} GiB; spilling coldest tolerant buffers"
        )
        overflow = total_fast - fast_cap
        slow_free = {t.name: float(t.capacity_bytes) for t in slows}
        # coldest first: bytes touched per step per resident byte; devices
        # fill in declaration order (the operator lists the preferred —
        # fastest — device first).
        for b in sorted(tolerant, key=lambda b: b.profile.bytes_per_step / max(b.nbytes, 1)):
            if overflow <= 0:
                break
            for t in slows:
                if overflow <= 0 or slow_free[t.name] <= 0:
                    continue
                move = min(b.nbytes * (1.0 - frac_of(b.name)), overflow,
                           slow_free[t.name])
                if move <= 0:
                    continue
                share = move / b.nbytes
                dev_frac[b.name][t.name] = (
                    dev_frac[b.name].get(t.name, 0.0) + share)
                overflow -= move
                slow_free[t.name] -= move
            if frac_of(b.name) > 0:
                reason[b.name] = (
                    f"capacity spill: {frac_of(b.name)*b.nbytes/2**30:.2f} "
                    f"GiB -> {'+'.join(dev_frac[b.name])} (guideline 4)")
        if overflow > 0:
            # Even the slow devices cannot absorb it; surface as failure.
            raise MemoryError(
                f"placement infeasible: {overflow/2**30:.2f} GiB cannot be "
                "placed after spilling all tolerant buffers"
            )

    # Everything placed so far is there because it must be (capacity); the
    # bandwidth-balancing step below only ever adds voluntary slow share.
    floor = {b.name: frac_of(b.name) for b in buffers}

    # --- step 4: bandwidth balancing --------------------------------------
    bw_weights = topology.bandwidth_weights(OpClass.LOAD)
    agg_slow_bw = sum(topology.effective_bw(t) for t in slows)
    rfo_avg = sum(t.rfo_traffic_multiplier * w
                  for t, w in zip(slows, bw_weights))
    store_ratio = sum(t.store_bw / t.load_bw * w
                      for t, w in zip(slows, bw_weights))

    def stream_bytes(on_slow: bool) -> float:
        total = 0.0
        for b in buffers:
            f = frac_of(b.name)
            share = f if on_slow else (1.0 - f)
            w_mult = 1.0 if b.profile.bytes_written_per_step == 0 else (
                rfo_avg if on_slow else 1.0
            )
            total += share * (
                b.profile.bytes_read_per_step
                + b.profile.bytes_written_per_step * w_mult
            )
        return total

    fast_time = stream_bytes(False) / fast.load_bw
    slow_time = stream_bytes(True) / agg_slow_bw
    if fast_time > compute_seconds and fast_time > slow_time:
        # Fast tier is the bottleneck: shift streaming bytes until the
        # tiers' transfer times equalize (or tolerance runs out).
        F, S = stream_bytes(False), stream_bytes(True)
        x_star = (F * agg_slow_bw - S * fast.load_bw) / (fast.load_bw + agg_slow_bw)
        moved = 0.0
        notes.append(
            f"fast tier bandwidth-bound ({fast_time*1e3:.2f} ms > compute "
            f"{compute_seconds*1e3:.2f} ms); target shift {x_star/2**30:.2f} GiB/step"
        )
        # hottest *streaming* buffers move first: they carry bytes/step
        # with the least capacity cost.
        for b in sorted(
            tolerant,
            key=lambda b: -(b.profile.bytes_per_step / max(b.nbytes, 1)),
        ):
            if moved >= x_star:
                break
            if bound[b.name] != Boundedness.BANDWIDTH_BOUND:
                continue
            movable = (1.0 - frac_of(b.name)) * b.profile.bytes_per_step
            # guideline: damp write-heavy spills by writer limits + RFO
            w = b.profile.bytes_written_per_step / max(b.profile.bytes_per_step, 1)
            damp = 1.0 - w * (1.0 - store_ratio)
            take = min(movable * damp, x_star - moved)
            if take <= 0:
                continue
            df = take / max(b.profile.bytes_per_step, 1)
            # Fig. 10 seeding: split the voluntary share across devices
            # proportional to their effective bandwidth.
            for t, bw_w in zip(slows, bw_weights):
                dev_frac[b.name][t.name] = (
                    dev_frac[b.name].get(t.name, 0.0) + df * bw_w)
            reason[b.name] = (
                f"bandwidth balance: +{df*100:.1f}% -> "
                f"{'+'.join(t.name for t in slows)} (Fig.9/10 regime)"
            )
            moved += take

    # --- step 6: arbiter-aware seeding ------------------------------------
    if write_budget_bw is not None and write_budget_bw > 0:
        step_s = max(compute_seconds, 1e-9)
        def write_rate(b: BufferReq, f: float) -> float:
            return f * b.profile.bytes_written_per_step * rfo_avg / step_s
        total_rate = sum(write_rate(b, frac_of(b.name)) for b in buffers)
        if total_rate > write_budget_bw:
            floor_rate = sum(write_rate(b, floor[b.name]) for b in buffers)
            vol_rate = total_rate - floor_rate
            scale = max(0.0, (write_budget_bw - floor_rate)
                        / max(vol_rate, 1e-12))
            scale = min(scale, 1.0)
            for b in buffers:
                f = frac_of(b.name)
                if f <= floor[b.name] + 1e-12:
                    continue
                keep = (floor[b.name] + (f - floor[b.name]) * scale) / f
                dev_frac[b.name] = {d: v * keep
                                    for d, v in dev_frac[b.name].items()}
                reason[b.name] += f" [budget-seeded x{scale:.2f}]"
            notes.append(
                f"arbiter-aware seeding: slow write demand "
                f"{total_rate:.3g} B/s > budget {write_budget_bw:.3g} B/s; "
                f"voluntary slow share scaled x{scale:.2f} at plan time")

    return _finalize(buffers, dev_frac, bound, reason, floor, ledger,
                     topology, fast_name, compute_seconds, notes,
                     slow_name=slow_name)


def hot_set_seed(scores, topology: TierTopology, *,
                 fast_budget_fraction: float = 0.5,
                 target_hot_traffic: float = 0.8) -> tuple[float, ...]:
    """Caption weight-vector seed for a SEMANTIC buffer (core/hotness.py).

    Given per-key hotness ``scores`` (a :class:`HotnessLedger`'s view),
    find the smallest hot-set fraction whose keys carry
    ``target_hot_traffic`` of the observed traffic — the knee of the
    skew CDF — capped by the fast tier's page budget, and split the
    cold remainder across the slow devices proportional to their
    effective bandwidth (the Fig. 10 best-static-ratio prior).  The
    returned tuple is the per-slow-device share vector a
    :class:`~repro.core.caption.CaptionController` walks from; with no
    observed traffic (cold start) the whole budget seeds hot."""
    s = np.sort(np.asarray(scores, np.float64))[::-1]
    n = max(s.size, 1)
    total = float(s.sum())
    budget = min(max(float(fast_budget_fraction), 0.0), 1.0)
    if total <= 0:
        hot_frac = budget
    else:
        cum = np.cumsum(s) / total
        knee = int(np.searchsorted(cum, min(max(target_hot_traffic, 0.0),
                                            1.0))) + 1
        hot_frac = min(knee / n, budget)
    cold = 1.0 - hot_frac
    slows = topology.slows
    if not slows:
        return ()
    bw = topology.bandwidth_weights(OpClass.LOAD)
    return tuple(cold * w for w in bw)


def _finalize(buffers, dev_frac, bound, reason, floor, ledger, topology,
              fast_name, compute_seconds, notes,
              slow_name: Optional[str] = None) -> Plan:
    fast = topology.fast
    slows = topology.slows
    decisions = {}
    fast_stream = 0.0
    slow_stream = {t.name: 0.0 for t in slows}
    two_device = len(slows) <= 1
    for b in buffers:
        fr = dev_frac[b.name]
        f = sum(fr.values())
        if two_device:
            # Two-device compatibility: keep the legacy round-up N:M policy
            # (capacity spills must never under-shoot) and honor a
            # slow_name override.
            sname = slow_name or (slows[0].name if slows else fast_name)
            policy = MemPolicy.from_slow_fraction(fast_name, sname, f,
                                                 round_up=True)
            f_eff = policy.slow_fraction(fast_name)
            eff_fr = {sname: f_eff} if f_eff > 0 else {}
        else:
            names = [t.name for t in slows]
            free = {t.name: t.capacity_bytes - ledger.used(t.name)
                    for t in slows}
            eff_fr = _quantize_device_fractions(
                {n: fr.get(n, 0.0) for n in names}, b.nbytes, free)
            policy = MemPolicy.from_tier_fractions(
                fast_name, names, [eff_fr.get(n, 0.0) for n in names],
                exact=True)
            f_eff = sum(eff_fr.values())
        decisions[b.name] = Decision(
            b.name, policy, f_eff, bound[b.name], reason[b.name],
            min_slow_fraction=floor.get(b.name, 0.0),
            device_fractions=eff_fr)
        ledger.register(b.name, fast_name, int(b.nbytes * (1 - f_eff)),
                        strict=False)
        for dname, share in eff_fr.items():
            ledger.register(b.name, dname, int(b.nbytes * share),
                            strict=False)
        fast_stream += (1 - f_eff) * b.profile.bytes_per_step
        for t in slows:
            share = eff_fr.get(t.name, 0.0)
            if share <= 0:
                continue
            w_mult = t.rfo_traffic_multiplier
            slow_stream[t.name] += share * (
                b.profile.bytes_read_per_step
                + b.profile.bytes_written_per_step * w_mult)
    ledger.check()
    est_fast = fast_stream / fast.load_bw
    # Devices stream in parallel: the slow-side time is the slowest device.
    est_slow = max(
        (slow_stream[t.name] / topology.effective_bw(t) for t in slows),
        default=0.0)
    return Plan(
        decisions=decisions,
        ledger=ledger,
        est_fast_seconds=est_fast,
        est_slow_seconds=est_slow,
        est_step_seconds=max(compute_seconds, est_fast, est_slow),
        notes=notes,
    )
