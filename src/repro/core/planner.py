"""Bandwidth-aware placement planner — the paper's guidelines, mechanized.

Given the access profile of every named buffer in a training/serving
step and a two-tier topology, produce a placement plan that applies §6:

  1. latency-bound buffers (µs-SLO state, recurrent state, pointer-chase
     structures) are *pinned to the fast tier* (guideline: "avoid running
     µs-latency state entirely on CXL");
  2. if everything fits in the fast tier and the fast tier is not
     bandwidth-saturated, everything stays fast (Fig. 7: interleaving
     cannot beat pure DRAM for a latency-bound app);
  3. capacity overflow spills the *coldest tolerant* buffers (lowest
     bytes-touched-per-step per resident byte) to the slow tier via
     weighted N:M interleave;
  4. if the fast tier is bandwidth-bound (streamed bytes/step over fast
     bandwidth exceeds compute time), shift streaming bytes to the slow
     tier until per-step transfer times equalize — the Fig. 9 SNC result
     (+11% at 20% CXL) generalized:
        x* = (F*Bs - S*Bf) / (Bf + Bs)   bytes/step moved to slow;
  5. write-heavy buffers have their slow fraction damped by the
     store/load bandwidth ratio and the writer limit (guideline: limit
     concurrent writers; RFO doubles temporal-store traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.classifier import AccessProfile, Boundedness, classify
from repro.core.ledger import TierLedger
from repro.core.policy import BufferClass, MemPolicy
from repro.core.tiers import TierTopology


@dataclasses.dataclass(frozen=True)
class BufferReq:
    """One logical buffer the planner must place."""

    name: str
    klass: BufferClass
    nbytes: int
    profile: AccessProfile
    #: hard pin (e.g. staging buffers, decode state)
    pin_fast: bool = False
    #: page size for the interleave policy this buffer will use
    page_bytes: int = 2 * 1024 * 1024


@dataclasses.dataclass
class Decision:
    buffer: str
    policy: MemPolicy
    slow_fraction: float
    boundedness: Boundedness
    reason: str
    #: capacity floor: the slow fraction forced by fast-tier overflow.  A
    #: dynamic controller (core/caption.py) may tune the fraction but can
    #: never go below this without re-overflowing the fast tier.
    min_slow_fraction: float = 0.0


@dataclasses.dataclass
class Plan:
    decisions: dict[str, Decision]
    ledger: TierLedger
    est_fast_seconds: float
    est_slow_seconds: float
    est_step_seconds: float
    notes: list[str]

    def slow_fraction(self, name: str) -> float:
        return self.decisions[name].slow_fraction

    def report(self) -> str:
        lines = [
            f"{'buffer':<28s} {'class':<12s} {'bound':<10s} {'slow%':>6s}  reason"
        ]
        for d in self.decisions.values():
            lines.append(
                f"{d.buffer:<28s} {'':<12s} {d.boundedness.value:<10s}"
                f" {d.slow_fraction*100:5.1f}%  {d.reason}"
            )
        lines.append(self.ledger.report())
        lines.append(
            f"est step: fast {self.est_fast_seconds*1e3:.3f} ms / "
            f"slow {self.est_slow_seconds*1e3:.3f} ms / "
            f"total {self.est_step_seconds*1e3:.3f} ms"
        )
        return "\n".join(lines)


_LATENCY_CLASSES = {BufferClass.RECURRENT_STATE}


def plan(
    buffers: Sequence[BufferReq],
    topology: TierTopology,
    *,
    compute_seconds: float,
    reserve_fast_bytes: int = 0,
    fast_name: Optional[str] = None,
    slow_name: Optional[str] = None,
) -> Plan:
    fast = topology.fast
    slow = topology.slow
    fast_name = fast_name or fast.name
    slow_name = slow_name or (slow.name if slow else fast.name)
    notes: list[str] = []
    ledger = TierLedger(topology)
    if reserve_fast_bytes:
        ledger.register("__reserved__", fast_name, reserve_fast_bytes,
                        note="activations/temps (XLA)", strict=False)

    frac: dict[str, float] = {}
    bound: dict[str, Boundedness] = {}
    reason: dict[str, str] = {}
    tolerant: list[BufferReq] = []

    for b in buffers:
        bd = classify(b.profile, slow if slow else fast)
        bound[b.name] = bd
        if b.pin_fast or b.klass in _LATENCY_CLASSES or bd == Boundedness.LATENCY_BOUND:
            frac[b.name] = 0.0
            reason[b.name] = "latency-bound/pinned -> fast tier (guideline 5)"
        else:
            frac[b.name] = 0.0
            reason[b.name] = "fits fast"
            tolerant.append(b)

    if slow is None:
        return _finalize(buffers, frac, bound, reason, dict(frac), ledger,
                         topology, fast_name, slow_name, compute_seconds,
                         notes)

    # --- step 3: capacity -----------------------------------------------
    fast_cap = fast.capacity_bytes - reserve_fast_bytes
    total_fast = sum(b.nbytes for b in buffers)
    if total_fast > fast_cap:
        notes.append(
            f"fast-tier overflow: {total_fast/2**30:.1f} GiB demand vs "
            f"{fast_cap/2**30:.1f} GiB; spilling coldest tolerant buffers"
        )
        overflow = total_fast - fast_cap
        slow_free = slow.capacity_bytes
        # coldest first: bytes touched per step per resident byte
        for b in sorted(tolerant, key=lambda b: b.profile.bytes_per_step / max(b.nbytes, 1)):
            if overflow <= 0 or slow_free <= 0:
                break
            move = min(b.nbytes, overflow, slow_free)
            frac[b.name] = max(frac[b.name], move / b.nbytes)
            reason[b.name] = (
                f"capacity spill: {move/2**30:.2f} GiB -> {slow_name} (guideline 4)"
            )
            overflow -= move
            slow_free -= move
        if overflow > 0:
            # Even the slow tier cannot absorb it; surface as plan failure.
            raise MemoryError(
                f"placement infeasible: {overflow/2**30:.2f} GiB cannot be "
                "placed after spilling all tolerant buffers"
            )

    # Everything placed so far is there because it must be (capacity); the
    # bandwidth-balancing step below only ever adds voluntary slow share.
    floor = dict(frac)

    # --- step 4: bandwidth balancing --------------------------------------
    def stream_bytes(on_slow: bool) -> float:
        total = 0.0
        for b in buffers:
            f = frac[b.name]
            share = f if on_slow else (1.0 - f)
            w_mult = 1.0 if b.profile.bytes_written_per_step == 0 else (
                slow.rfo_traffic_multiplier if on_slow else 1.0
            )
            total += share * (
                b.profile.bytes_read_per_step
                + b.profile.bytes_written_per_step * w_mult
            )
        return total

    slow_bw = min(slow.load_bw, slow.link_bw or slow.load_bw)
    fast_time = stream_bytes(False) / fast.load_bw
    slow_time = stream_bytes(True) / slow_bw
    if fast_time > compute_seconds and fast_time > slow_time:
        # Fast tier is the bottleneck: shift streaming bytes until the
        # two tiers' transfer times equalize (or tolerance runs out).
        F, S = stream_bytes(False), stream_bytes(True)
        x_star = (F * slow_bw - S * fast.load_bw) / (fast.load_bw + slow_bw)
        moved = 0.0
        notes.append(
            f"fast tier bandwidth-bound ({fast_time*1e3:.2f} ms > compute "
            f"{compute_seconds*1e3:.2f} ms); target shift {x_star/2**30:.2f} GiB/step"
        )
        # hottest *streaming* buffers move first: they carry bytes/step
        # with the least capacity cost.
        for b in sorted(
            tolerant,
            key=lambda b: -(b.profile.bytes_per_step / max(b.nbytes, 1)),
        ):
            if moved >= x_star:
                break
            if bound[b.name] != Boundedness.BANDWIDTH_BOUND:
                continue
            movable = (1.0 - frac[b.name]) * b.profile.bytes_per_step
            # guideline: damp write-heavy spills by writer limits + RFO
            w = b.profile.bytes_written_per_step / max(b.profile.bytes_per_step, 1)
            damp = 1.0 - w * (1.0 - slow.store_bw / slow.load_bw)
            take = min(movable * damp, x_star - moved)
            if take <= 0:
                continue
            df = take / max(b.profile.bytes_per_step, 1)
            frac[b.name] = min(1.0, frac[b.name] + df)
            reason[b.name] = (
                f"bandwidth balance: +{df*100:.1f}% -> {slow_name} (Fig.9 regime)"
            )
            moved += take

    return _finalize(buffers, frac, bound, reason, floor, ledger, topology,
                     fast_name, slow_name, compute_seconds, notes)


def _finalize(buffers, frac, bound, reason, floor, ledger, topology,
              fast_name, slow_name, compute_seconds, notes) -> Plan:
    fast = topology.fast
    slow = topology.slow
    decisions = {}
    fast_stream = 0.0
    slow_stream = 0.0
    for b in buffers:
        f = frac[b.name]
        policy = MemPolicy.from_slow_fraction(fast_name, slow_name, f,
                                              round_up=True)
        f_eff = policy.slow_fraction(fast_name)
        decisions[b.name] = Decision(b.name, policy, f_eff, bound[b.name],
                                     reason[b.name],
                                     min_slow_fraction=floor.get(b.name, 0.0))
        ledger.register(b.name, fast_name, int(b.nbytes * (1 - f_eff)), strict=False)
        if f_eff > 0:
            ledger.register(b.name, slow_name, int(b.nbytes * f_eff), strict=False)
        w_mult = slow.rfo_traffic_multiplier if slow else 1.0
        fast_stream += (1 - f_eff) * b.profile.bytes_per_step
        slow_stream += f_eff * (
            b.profile.bytes_read_per_step + b.profile.bytes_written_per_step * w_mult
        )
    ledger.check()
    slow_bw = min(slow.load_bw, slow.link_bw or slow.load_bw) if slow else fast.load_bw
    est_fast = fast_stream / fast.load_bw
    est_slow = slow_stream / slow_bw
    return Plan(
        decisions=decisions,
        ledger=ledger,
        est_fast_seconds=est_fast,
        est_slow_seconds=est_slow,
        est_step_seconds=max(compute_seconds, est_fast, est_slow),
        notes=notes,
    )
