"""TierMesh core — the paper's contribution as a composable library.

Demystifying CXL Memory (Sun et al., MICRO'23), adapted to TPU pods:
tier characterization (tiers/perfmodel/memo), placement policies
(policy/planner/classifier), page interleaving (interleave), bulk
movement (mover), and capacity accounting (ledger).
"""
from repro.core.arbiter import ArbiterConfig, CaptionArbiter
from repro.core.caption import (
    CaptionConfig,
    CaptionController,
    EpochMetrics,
)
from repro.core.classifier import AccessProfile, Boundedness, classify
from repro.core.interleave import InterleavedTensor
from repro.core.ledger import CapacityError, TierLedger
from repro.core.mover import BulkMover, Descriptor, double_buffer
from repro.core.planner import BufferReq, Decision, Plan, plan
from repro.core.policy import BufferClass, MemPolicy, PolicyKind
from repro.core.tiers import (
    CXL_A,
    CXL_AGILEX,
    CXL_B,
    CXL_C,
    DDR5_L8,
    DDR5_R1,
    DEVICE_REGISTRY,
    HBM_V5E,
    HOST_V5E,
    OpClass,
    TierSpec,
    TierTopology,
    paper_three_device_topology,
    paper_topology,
    topology_from_spec,
    tpu_v5e_topology,
)

__all__ = [
    "ArbiterConfig", "CaptionArbiter",
    "CaptionConfig", "CaptionController", "EpochMetrics",
    "AccessProfile", "Boundedness", "classify",
    "InterleavedTensor", "CapacityError", "TierLedger",
    "BulkMover", "Descriptor", "double_buffer",
    "BufferReq", "Decision", "Plan", "plan",
    "BufferClass", "MemPolicy", "PolicyKind",
    "OpClass", "TierSpec", "TierTopology",
    "CXL_A", "CXL_AGILEX", "CXL_B", "CXL_C",
    "DDR5_L8", "DDR5_R1", "DEVICE_REGISTRY", "HBM_V5E", "HOST_V5E",
    "paper_three_device_topology", "paper_topology", "topology_from_spec",
    "tpu_v5e_topology",
]
