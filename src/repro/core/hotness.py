"""Hotness-driven semantic tiering (ISSUE 10).

The paper's DLRM result (Figs. 8/9) shows bandwidth-bound embedding
reduction is exactly the workload that *gains* from CXL interleaving —
but only if the hot working set stays on the fast tier.  The page
machinery below this module is address-anonymous: a Zipf-hot embedding
row or a heavily-routed MoE expert is as likely to land on the slowest
CXL device as a cold one.  This module makes placement *semantic*:

* :class:`HotnessLedger` — EWMA-decayed per-key access counters, fed
  for free from MoE router dispatch counts (``aux["expert_counts"]``
  in :mod:`repro.models.moe`) and embedding gather indices.  Its
  :meth:`~HotnessLedger.topk_split` ranks keys hottest-first; the
  placement planner maps the hot split to fast-pinned pages and the
  cold split to a bandwidth-weighted interleave across the CXL
  devices (the Fig. 10 best-static-ratio prior).
* :class:`SemanticTensor` — a view over
  :class:`~repro.core.interleave.InterleavedTensor` that groups rows
  (or flattened experts) into placement *keys* of ``rows_per_key``
  rows.  A key's pages are page-aligned and contiguous, so promotion/
  demotion rides the existing O(Δ) run-coalesced actuation path:
  billed routes, optional donation, shape-stable shards — a hotness
  shift never retraces jitted consumers.
* :class:`HotSetCoordinator` — Caption integration: the hot-set size
  is a *walked coordinate*.  The controller's slow-share weight vector
  is reinterpreted semantically (fast share = hottest keys by rank,
  slow shares = cold keys dealt bandwidth-proportionally), so the
  walk trades fast-tier pages between the hot set and everything else
  under the arbiter's budget, and hot-set membership *drift* re-opens
  a converged walk exactly like route-bandwidth drift does.

Gated end-to-end by ``benchmarks/bench_hotness.py``: hotness-aware
placement strictly beats hotness-blind uniform interleave on modeled
throughput under Zipf skew, outputs stay bit-exact, and a mid-run skew
flip re-tiers in O(moved-keys) descriptors with zero retraces.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.interleave import InterleavedTensor, _ExplicitAssignment
from repro.core.policy import largest_remainder_split
from repro.core.telemetry import GLOBAL_TELEMETRY, Telemetry


class HotnessLedger:
    """EWMA-decayed per-key access-frequency counters.

    Keys are whatever the semantic layer places: MoE experts, embedding
    row blocks, table shards.  Traffic is recorded *into the current
    epoch* (:meth:`record` for ready-made count vectors like the MoE
    router's dispatch histogram, :meth:`record_keys` /
    :meth:`record_rows` for index streams); :meth:`tick` folds the
    epoch into the EWMA (``ewma = decay * ewma + epoch``) so a key
    that stops being accessed decays toward cold at ``decay`` per
    epoch instead of staying hot forever.  :meth:`scores` includes the
    partially-accumulated current epoch, so placement decisions made
    mid-epoch see the freshest traffic.
    """

    def __init__(self, n_keys: int, *, decay: float = 0.8):
        if n_keys <= 0:
            raise ValueError("n_keys must be positive")
        if not 0.0 <= decay < 1.0:
            raise ValueError("decay must be in [0, 1)")
        self.n_keys = int(n_keys)
        self.decay = float(decay)
        self._ewma = np.zeros(self.n_keys, np.float64)
        self._epoch = np.zeros(self.n_keys, np.float64)
        self.epochs = 0
        self.total_observed = 0.0
        #: hot-set reference for drift detection (see :meth:`mark`).
        self._marked: Optional[frozenset] = None

    # -- feeding -------------------------------------------------------------
    def record(self, counts) -> None:
        """Add a per-key count vector (e.g. MoE ``aux["expert_counts"]``)."""
        c = np.asarray(counts, np.float64).reshape(-1)
        if c.shape != (self.n_keys,):
            raise ValueError(
                f"count vector has {c.shape[0]} entries, ledger has "
                f"{self.n_keys} keys")
        self._epoch += c

    def record_keys(self, keys, weights=None) -> None:
        """Add an access-stream of key ids (embedding gather granularity)."""
        k = np.asarray(keys).reshape(-1)
        if k.size == 0:
            return
        if k.min() < 0 or k.max() >= self.n_keys:
            raise ValueError("key id out of range")
        w = (np.ones(k.size, np.float64) if weights is None
             else np.asarray(weights, np.float64).reshape(-1))
        np.add.at(self._epoch, k, w)

    def record_rows(self, rows, rows_per_key: int) -> None:
        """Add a row-index stream, mapping rows onto their owning key."""
        r = np.asarray(rows).reshape(-1)
        if r.size == 0:
            return
        self.record_keys(r // int(rows_per_key))

    def tick(self) -> float:
        """Close the epoch: decay the EWMA and fold the epoch counts in.

        Returns the raw traffic observed this epoch (for telemetry)."""
        observed = float(self._epoch.sum())
        self._ewma = self.decay * self._ewma + self._epoch
        self._epoch = np.zeros(self.n_keys, np.float64)
        self.epochs += 1
        self.total_observed += observed
        return observed

    # -- ranking -------------------------------------------------------------
    def scores(self) -> np.ndarray:
        """Current per-key hotness (EWMA + the in-flight epoch)."""
        return self._ewma + self._epoch

    def rank(self) -> np.ndarray:
        """Key ids sorted hottest-first (stable: ties keep id order)."""
        return np.argsort(-self.scores(), kind="stable")

    def topk_split(self, n_hot: int) -> tuple[np.ndarray, np.ndarray]:
        """(hot keys, cold keys): the ``n_hot`` hottest keys by rank and
        the remainder, both hottest-first.  The placement contract: hot
        keys map to fast-pinned pages, cold keys to the bandwidth-
        weighted CXL interleave (:func:`semantic_assignment`)."""
        n_hot = int(np.clip(n_hot, 0, self.n_keys))
        r = self.rank()
        return r[:n_hot], r[n_hot:]

    def traffic_share(self, keys) -> float:
        """Fraction of total observed traffic attributed to ``keys``."""
        s = self.scores()
        total = float(s.sum())
        if total <= 0:
            return 0.0
        return float(s[np.asarray(keys, np.int64)].sum()) / total

    # -- hot-set drift -------------------------------------------------------
    def mark(self, n_hot: int) -> None:
        """Remember the current top-``n_hot`` set as the drift reference
        (called by the semantic layer at every actuated placement)."""
        hot, _ = self.topk_split(n_hot)
        self._marked = frozenset(int(k) for k in hot)

    def drift(self) -> float:
        """Fraction of the marked hot set that is no longer hot.

        0.0 = membership unchanged (or nothing marked yet); 1.0 = the
        entire marked set fell out of the top-k.  The
        :class:`HotSetCoordinator` compares this against its threshold
        to re-open a converged Caption walk — the semantic analogue of
        the controller's route-bandwidth drift detector."""
        if not self._marked:
            return 0.0
        hot, _ = self.topk_split(len(self._marked))
        still = len(self._marked.intersection(int(k) for k in hot))
        return 1.0 - still / len(self._marked)


def semantic_assignment(
    n_keys: int,
    pages_per_key: int,
    hot_keys: np.ndarray,
    cold_keys: np.ndarray,
    weights: Sequence[float],
) -> np.ndarray:
    """Page -> device-ordinal map from a hot/cold key split.

    Hot keys pin to the fast tier (device 0).  Cold keys are dealt
    across the slow devices in hotness-rank order with largest-remainder
    quotas proportional to ``weights`` (the caller passes bandwidth
    weights or the Caption walk's per-device shares), interleaved so
    consecutive-rank cold keys alternate devices — the semantic
    counterpart of the N:M page interleave.  Every key's pages are
    contiguous (key ``k`` owns pages ``[k*ppk, (k+1)*ppk)``), so a
    later promotion/demotion of one key ships as one contiguous run."""
    key_dev = np.zeros(n_keys, np.int8)
    n_cold = len(cold_keys)
    if n_cold:
        w = np.maximum(np.asarray(list(weights), np.float64), 0.0)
        if w.sum() <= 0:
            w = np.ones(len(w) or 1)
        quotas, _ = largest_remainder_split(
            (w / w.sum() * n_cold).tolist(), n_cold)
        # Interleave the dealt devices: device d contributes quotas[d]
        # evenly spaced picks over the cold rank order.
        order_pos = np.concatenate([
            (np.arange(q) + 0.5) / q for q in quotas if q > 0
        ]) if any(q > 0 for q in quotas) else np.zeros(0)
        order_dev = np.concatenate([
            np.full(q, d + 1, np.int8) for d, q in enumerate(quotas) if q > 0
        ]) if any(q > 0 for q in quotas) else np.zeros(0, np.int8)
        dealt = order_dev[np.argsort(order_pos, kind="stable")]
        key_dev[np.asarray(cold_keys, np.int64)] = dealt
    key_dev[np.asarray(hot_keys, np.int64)] = 0
    return np.repeat(key_dev, int(pages_per_key))


@dataclasses.dataclass
class SemanticTensor:
    """Hotness-aware placement view over an :class:`InterleavedTensor`.

    Rows are grouped into placement keys of ``rows_per_key`` rows; each
    key owns ``rows_per_key / page_rows`` whole pages, contiguous in
    page-id space.  All data-plane access (gather / scatter /
    bag_reduce) delegates to the underlying tensor — and records the
    touched keys into the :class:`HotnessLedger` when the indices are
    concrete, so serving traffic feeds the placement loop for free.

    :meth:`retier` re-plans placement from the ledger's current ranking
    under a Caption weight vector and actuates the delta through the
    tensor's run-coalesced O(Δ) path.  With ``headroom`` sized by
    :meth:`CaptionController.headroom_pages` the whole walk is
    shape-stable: zero retraces across any sequence of hotness shifts.
    """

    it: InterleavedTensor
    rows_per_key: int
    ledger: HotnessLedger
    #: logical (un-padded) row count of the source array.
    logical_rows: int
    #: actuation summary of the last :meth:`retier` call.
    last_retier: dict = dataclasses.field(default_factory=dict)

    # -- construction --------------------------------------------------------
    @classmethod
    def from_array(
        cls,
        array: jax.Array,
        *,
        rows_per_key: int,
        weights: Sequence[float],
        device_names: Sequence[str] = ("fast", "slow"),
        page_rows: Optional[int] = None,
        placement: str = "blind",
        ledger: Optional[HotnessLedger] = None,
        decay: float = 0.8,
        headroom: int = 0,
        backend: str = "modeled",
    ) -> "SemanticTensor":
        """Build over ``array`` with slow-share ``weights`` (one entry
        per slow device in ``device_names[1:]``; the fast tier keeps the
        remainder).

        ``placement="blind"`` starts hotness-anonymous — an N:M
        interleave in address order, the exact baseline the bench
        compares against; ``"semantic"`` places by the (possibly
        pre-seeded) ledger ranking immediately."""
        rows_per_key = int(rows_per_key)
        page_rows = int(page_rows or rows_per_key)
        if rows_per_key % page_rows:
            raise ValueError("rows_per_key must be a multiple of page_rows")
        rows = array.shape[0]
        n_keys = max(1, math.ceil(rows / rows_per_key))
        pad = n_keys * rows_per_key - rows
        if pad:
            import jax.numpy as jnp
            array = jnp.concatenate(
                [array, jnp.zeros((pad,) + array.shape[1:], array.dtype)])
        led = ledger or HotnessLedger(n_keys, decay=decay)
        if led.n_keys != n_keys:
            raise ValueError(
                f"ledger has {led.n_keys} keys, tensor has {n_keys}")
        ppk = rows_per_key // page_rows
        names = tuple(device_names)
        n_pages = n_keys * ppk
        if placement == "semantic":
            assign = cls._plan(led, n_keys, ppk, tuple(weights))
        elif placement == "blind":
            # hotness-anonymous baseline: the N:M uniform interleave in
            # address order (key id, not rank) — exactly what the page
            # machinery did before this layer existed.
            from repro.core.interleave import _policy_device_map
            from repro.core.policy import MemPolicy
            # smallest-cycle discipline: a full denominator-length blocky
            # cycle would leave a small tensor entirely on the fast tier
            pol = MemPolicy.from_tier_fractions(
                names[0], list(names[1:]), list(weights))
            key_assign, _ = _policy_device_map(pol, n_keys)
            assign = np.repeat(np.asarray(key_assign, np.int8), ppk)
        else:
            raise ValueError(f"unknown placement {placement!r}")
        it = InterleavedTensor.from_array(
            array, _ExplicitAssignment(assign[:n_pages], names), page_rows,
            headroom=headroom, backend=backend)
        st = cls(it=it, rows_per_key=rows_per_key, ledger=led,
                 logical_rows=rows)
        led.mark(st.hot_keys())
        return st

    # -- derived -------------------------------------------------------------
    @property
    def n_keys(self) -> int:
        return self.ledger.n_keys

    @property
    def pages_per_key(self) -> int:
        return self.rows_per_key // self.it.page_rows

    def key_device(self) -> np.ndarray:
        """(n_keys,) owning device of each key's FIRST page (keys placed
        semantically sit wholly on one device; a blind start may split)."""
        dev, _ = self.it._host_map()
        return dev[:: self.pages_per_key].copy()

    def hot_keys(self) -> int:
        """Number of keys currently resident on the fast tier."""
        return int((self.key_device() == 0).sum())

    def hot_traffic_share(self) -> float:
        """Observed traffic share of the keys on the fast tier."""
        dev = self.key_device()
        return self.ledger.traffic_share(np.nonzero(dev == 0)[0])

    # -- data plane ----------------------------------------------------------
    def _record_idx(self, idx) -> None:
        if not isinstance(idx, jax.core.Tracer):
            self.ledger.record_rows(np.asarray(idx), self.rows_per_key)

    def gather_rows(self, idx) -> jax.Array:
        self._record_idx(idx)
        return self.it.gather_rows(idx)

    def update_rows(self, idx, values, *, donate: bool = False
                    ) -> "SemanticTensor":
        self._record_idx(idx)
        return dataclasses.replace(
            self, it=self.it.update_rows(idx, values, donate=donate))

    def bag_reduce(self, indices, weights=None, reduce_fn=None) -> jax.Array:
        """Embedding-bag reduction (DLRM §5.2) through the semantic
        layout; touched rows feed the hotness ledger when concrete."""
        self._record_idx(indices)
        return self.it.bag_reduce(indices, weights, reduce_fn=reduce_fn)

    def to_array(self) -> jax.Array:
        return self.it.to_array()[: self.logical_rows]

    # -- placement -----------------------------------------------------------
    @staticmethod
    def _plan(ledger: HotnessLedger, n_keys: int, ppk: int,
              weights: tuple[float, ...]) -> np.ndarray:
        slow_share = min(max(sum(weights), 0.0), 1.0)
        n_hot = n_keys - int(round(slow_share * n_keys))
        hot, cold = ledger.topk_split(n_hot)
        return semantic_assignment(n_keys, ppk, hot, cold,
                                   _cold_weights(weights))

    def plan_assignment(self, weights: Sequence[float]) -> np.ndarray:
        """The page -> device map :meth:`retier` would actuate for
        ``weights`` (per-slow-device page shares, Caption semantics)."""
        return self._plan(self.ledger, self.n_keys, self.pages_per_key,
                          tuple(weights))

    def retier(self, weights: Sequence[float], *, mover=None,
               telemetry: Telemetry = GLOBAL_TELEMETRY,
               source: Optional[str] = "hotness", lane: Optional[int] = None,
               donate: bool = False) -> "SemanticTensor":
        """Re-place by the CURRENT hotness ranking under ``weights``.

        Hot keys (by EWMA rank, filling the fast share ``1 -
        sum(weights)``) pin fast; cold keys interleave across the slow
        devices by the weight vector.  Only changed pages move — whole
        keys, as contiguous page runs — through
        :meth:`InterleavedTensor.reassign_pages`, so the descriptor
        count is O(moved keys), moves are billed to their real routes,
        and a shape-stable tensor never retraces its consumers.  A plan
        equal to the current map returns ``self`` untouched."""
        new_dev = self.plan_assignment(weights)
        old_dev, _ = self.it._host_map()
        moved = np.nonzero(new_dev != old_dev)[0]
        if moved.size == 0:
            self.ledger.mark(self.hot_keys())
            return self
        promoted = int((new_dev[moved] == 0).sum())
        demoted = int((old_dev[moved] == 0).sum())
        it2 = self.it.reassign_pages(new_dev, mover=mover,
                                     telemetry=telemetry, source=source,
                                     lane=lane, donate=donate)
        telemetry.record_semantic(promoted, demoted, source=source)
        moved_keys = int(np.unique(moved // self.pages_per_key).size)
        out = dataclasses.replace(
            self, it=it2,
            last_retier={
                "moved_pages": int(moved.size),
                "moved_keys": moved_keys,
                "promoted_pages": promoted,
                "demoted_pages": demoted,
            })
        out.ledger.mark(out.hot_keys())
        return out

    def drift(self) -> float:
        """Hot-set membership drift since the last actuated placement."""
        return self.ledger.drift()

    def placement_report(self) -> str:
        """Human-readable placement summary (examples / debugging)."""
        dev = self.key_device()
        s = self.ledger.scores()
        total = max(float(s.sum()), 1e-12)
        lines = [f"{'device':<12s} {'keys':>6s} {'pages':>7s} "
                 f"{'traffic%':>9s}"]
        fr = self.it.device_fractions()
        for i, name in enumerate(self.it.device_names):
            keys = np.nonzero(dev == i)[0]
            lines.append(
                f"{name:<12s} {keys.size:>6d} "
                f"{int(round(fr.get(name, 0.0) * self.it.n_pages)):>7d} "
                f"{100 * float(s[keys].sum()) / total:>8.1f}%")
        return "\n".join(lines)


def _cold_weights(weights: tuple[float, ...]) -> tuple[float, ...]:
    """Normalize a Caption slow-share vector into relative cold-deal
    quotas (all-zero falls back to an even split)."""
    total = sum(weights)
    if total <= 0:
        return tuple(1.0 for _ in weights) or (1.0,)
    return tuple(w / total for w in weights)


class HotSetCoordinator:
    """Caption glue: the hot-set size as a walked coordinate.

    Owns a :class:`SemanticTensor` and a
    :class:`~repro.core.caption.CaptionController` whose weight vector
    is reinterpreted semantically: ``1 - sum(weights)`` of the pages
    hold the hottest keys on the fast tier, the rest interleave across
    the CXL devices.  Each :meth:`epoch`:

    1. closes the ledger epoch (EWMA tick);
    2. while CONVERGED, compares the current hot-set ranking against a
       membership snapshot frozen WHEN the walk converged and re-opens
       beyond ``drift_threshold`` — the semantic analogue of the
       controller's route-bandwidth drift detector.  (The snapshot is
       deliberately not the ledger's own per-retier mark: step 4 keeps
       re-tiering every epoch, so per-retier drift resets each epoch
       and a gradual workload shift would track silently forever.
       Tracking handles WHO is hot; the re-open re-probes HOW MANY
       keys deserve fast pages under the shifted skew.)
    3. feeds the metrics to the controller (its growth stays gated by
       whatever :class:`~repro.core.arbiter.CaptionArbiter` budget the
       caller registered it under);
    4. actuates the decided weights through :meth:`SemanticTensor.retier`
       (O(moved-keys) descriptors; a pure hotness reshuffle at constant
       weights also actuates here) and feeds back the achieved shares.
    """

    def __init__(self, st: SemanticTensor, controller, *, mover=None,
                 telemetry: Telemetry = GLOBAL_TELEMETRY,
                 drift_threshold: float = 0.5,
                 source: str = "hotness", donate: bool = False):
        self.st = st
        self.controller = controller
        self.mover = mover
        self.telemetry = telemetry
        self.drift_threshold = float(drift_threshold)
        self.source = source
        self.donate = donate
        self.reopens = 0
        #: hot-set membership at the moment the walk converged.
        self._converged_hot: Optional[frozenset] = None

    def _snapshot(self) -> None:
        hot, _ = self.st.ledger.topk_split(self.st.hot_keys())
        self._converged_hot = frozenset(int(k) for k in hot)

    def drift(self) -> float:
        """Hot-set churn since the walk converged (0.0 while walking)."""
        ref = self._converged_hot
        if not ref:
            return 0.0
        hot, _ = self.st.ledger.topk_split(len(ref))
        return 1.0 - len(ref.intersection(int(k) for k in hot)) / len(ref)

    def epoch(self, metrics):
        """Feed one epoch's :class:`~repro.core.caption.EpochMetrics`;
        returns the controller's Decision after actuation."""
        self.st.ledger.tick()
        ctl = self.controller
        if ctl.converged:
            # lazy init covers controllers handed over already-converged
            if self._converged_hot is None:
                self._snapshot()
            churn = self.drift()
            if churn > self.drift_threshold:
                decision = ctl.reopen(
                    f"hot-set drift: {churn * 100:.0f}% of the converged "
                    "hot set fell out of the top-k")
                self.reopens += 1
                self._converged_hot = None
            else:
                decision = ctl.observe(metrics)
        else:
            self._converged_hot = None
            decision = ctl.observe(metrics)
            if ctl.converged:
                # snapshot AT the transition, before any post-convergence
                # traffic can contaminate the drift reference
                self._snapshot()
        self.st = self.st.retier(
            decision.weights, mover=self.mover, telemetry=self.telemetry,
            source=self.source, donate=self.donate)
        ctl.actuated_weights(self.st.it.weights())
        return decision
