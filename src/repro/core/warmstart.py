"""Warm-start memo: workload fingerprint -> converged Caption weights.

The paper's Caption loop (§7) converges by walking the slow-share
simplex from a cold prior — every probe epoch spent off the optimum is
regret paid in real bandwidth.  But production traffic recurs: the same
DLRM embedding mix, the same decode batch shape, the same topology.
This module gives the controller a memory: when a walk converges, the
converged weight vector is filed under a *workload fingerprint* built
from ``AccessProfile``-style features of the epoch telemetry (read/write
ratio against the slow pool, slow-route bandwidth, writer parallelism)
plus the topology signature.  A later run that fingerprints the same
workload seeds :class:`~repro.core.caption.CaptionController` at the
remembered optimum and enters MEASURE directly, skipping the walk.

Invalidation is structural, not temporal:

  * the **topology signature** (device names + load bandwidths) is part
    of the key — a hot-removed device or a different device mix can
    never resurrect weights measured against hardware that is gone;
  * the **drift signature** is checked at lookup: the entry remembers
    the raw slow-route bandwidth it fingerprinted at, and a candidate
    whose route bandwidth deviates beyond ``drift_threshold`` misses
    (same quantized bucket or not) — the §7 drift rule applied to the
    memo itself.

The store is a flat JSON file (``--memo-path`` in the serve/train
drivers): human-inspectable, safe to delete, empty-on-missing.  This is
deliberately separate machinery from :mod:`repro.core.memo`, which is
the paper's MEMO *bandwidth microbenchmark*; the two share only a name
lineage.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Optional, Sequence

from repro.core.tiers import TierTopology


def topology_signature(topology: TierTopology) -> str:
    """Stable identity of the device mix the weights were measured on.

    Names plus load bandwidths: a renamed device, a different CXL mix,
    or a degraded preset all produce a different signature (and so a
    different fingerprint key)."""
    parts = [f"{t.name}@{t.load_bw:.3g}" for t in topology.devices]
    return "+".join(parts)


@dataclasses.dataclass(frozen=True)
class WorkloadFingerprint:
    """AccessProfile-style identity of one epoch window's workload.

    Raw feature values are carried alongside so the memo can apply the
    drift check at lookup; :meth:`key` quantizes them into coarse
    buckets so *equivalent* windows (same workload, ordinary sampling
    jitter) collapse onto the same entry."""

    topology: str
    #: written / (read + written) bytes against the slow pool.
    write_ratio: float = 0.0
    #: slow-route bandwidth (bytes/s, both directions).
    slow_bw: float = 0.0
    #: writer parallelism (peak concurrent writers this window).
    parallelism: float = 0.0
    #: boundedness class of the buffer (§6.1 taxonomy).
    boundedness: str = "bandwidth"

    def key(self) -> str:
        """Quantized store key: eighth-steps of write ratio, log2 buckets
        of bandwidth and parallelism."""
        wr = int(round(min(max(self.write_ratio, 0.0), 1.0) * 8))
        bw = int(math.log2(self.slow_bw)) if self.slow_bw >= 1.0 else -1
        par = (int(math.log2(self.parallelism))
               if self.parallelism >= 1.0 else -1)
        return f"{self.topology}|wr{wr}|bw{bw}|par{par}|{self.boundedness}"


def fingerprint_metrics(metrics, topology: TierTopology,
                        boundedness: str = "bandwidth"
                        ) -> WorkloadFingerprint:
    """Fingerprint one :class:`~repro.core.caption.EpochMetrics`."""
    return WorkloadFingerprint(
        topology=topology_signature(topology),
        write_ratio=float(metrics.write_ratio),
        slow_bw=float(metrics.slow_bw),
        parallelism=float(metrics.writer_concurrency),
        boundedness=boundedness,
    )


def fingerprint_counters(counters, topology: TierTopology,
                         slow=None, boundedness: str = "bandwidth"
                         ) -> WorkloadFingerprint:
    """Fingerprint a raw :class:`~repro.core.telemetry.EpochCounters`
    window (the telemetry-side twin of :func:`fingerprint_metrics`)."""
    feats = counters.workload_features(
        slow if slow is not None else topology.slow_names)
    return WorkloadFingerprint(
        topology=topology_signature(topology),
        write_ratio=feats["write_ratio"],
        slow_bw=feats["slow_bw"],
        parallelism=feats["parallelism"],
        boundedness=boundedness,
    )


class WarmStartMemo:
    """Persistable fingerprint -> converged-weights store.

    ``lookup`` returns the remembered per-device weight vector or None;
    ``record`` files/refreshes an entry.  ``hits``/``misses``/
    ``drift_misses`` count lookup outcomes for driver logging."""

    def __init__(self, entries: Optional[dict] = None, *,
                 drift_threshold: float = 0.5):
        if drift_threshold < 0:
            raise ValueError("drift_threshold must be >= 0")
        self.drift_threshold = drift_threshold
        self._entries: dict[str, dict] = dict(entries or {})
        self.hits = 0
        self.misses = 0
        self.drift_misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> dict[str, dict]:
        return dict(self._entries)

    def record(self, fp: WorkloadFingerprint,
               weights: Sequence[float]) -> None:
        """File ``weights`` as the converged answer for ``fp`` (an
        existing entry for the same key is refreshed)."""
        self._entries[fp.key()] = {
            "weights": [float(w) for w in weights],
            "topology": fp.topology,
            "write_ratio": float(fp.write_ratio),
            "slow_bw": float(fp.slow_bw),
            "parallelism": float(fp.parallelism),
            "boundedness": fp.boundedness,
            "hits": self._entries.get(fp.key(), {}).get("hits", 0),
        }

    def lookup(self, fp: WorkloadFingerprint
               ) -> Optional[tuple[float, ...]]:
        """Remembered weights for ``fp``, or None.

        Misses on an unknown key, on a topology-signature mismatch, and
        on a drift-signature mismatch (raw slow-route bandwidth deviating
        beyond ``drift_threshold`` from the recorded one — within-bucket
        drift must not resurrect a stale operating point)."""
        e = self._entries.get(fp.key())
        if e is None or e.get("topology") != fp.topology:
            self.misses += 1
            return None
        held = float(e.get("slow_bw", 0.0))
        ref = max(held, fp.slow_bw)
        if ref > 0 and abs(fp.slow_bw - held) / ref > self.drift_threshold:
            self.drift_misses += 1
            self.misses += 1
            return None
        e["hits"] = int(e.get("hits", 0)) + 1
        self.hits += 1
        return tuple(float(w) for w in e["weights"])

    # -- persistence ---------------------------------------------------------
    def to_json(self) -> dict:
        return {"version": 1, "drift_threshold": self.drift_threshold,
                "entries": self._entries}

    @classmethod
    def from_json(cls, payload: dict) -> "WarmStartMemo":
        return cls(payload.get("entries", {}),
                   drift_threshold=float(
                       payload.get("drift_threshold", 0.5)))

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str, *,
             drift_threshold: Optional[float] = None) -> "WarmStartMemo":
        """Load a memo; a missing or unreadable file is an empty memo
        (the cold-start case must never crash the driver)."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            return cls(drift_threshold=(0.5 if drift_threshold is None
                                        else drift_threshold))
        memo = cls.from_json(payload)
        if drift_threshold is not None:
            memo.drift_threshold = drift_threshold
        return memo
